"""Model-parallel tree driver on a REAL (forced-host-device) mesh.

Subprocess with 4 host devices, mesh (2, 2) = ("data", "model"): the
sharded-corpus gather must land batch leaves on the worker-sharded layout
(`batch_pspec`), the corpus must stay replicated, and `launch/train.py`'s
tree layout must run the whole --rounds budget as ONE dispatch whose
per-round params are bit-identical to the legacy per-round `tree_round()`
path on the same q-matrix and index plan (ISSUE 4 acceptance).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, io, json
    from contextlib import redirect_stdout
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.core.straggler import StragglerModel
    from repro.data.pipeline import TokenBatcher
    from repro.data.synthetic import synthetic_tokens
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainPlan, make_train_engine
    from repro.models import model as M
    from repro.optim import sgd
    from repro.sharding.specs import (batch_pspec, corpus_shardings, named,
                                      param_pspecs)

    mp, W, QMAX, B, K, SEQ = 2, 2, 2, 2, 3, 32
    mesh = make_host_mesh(mp)
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              model_parallel=mp)
    rng = np.random.default_rng(0)
    toks = synthetic_tokens(rng, 64, SEQ, cfg.vocab)
    bt = TokenBatcher(toks, W, 1, QMAX, B, seed=0)
    csh, bsh = corpus_shardings(bt.inner.arrays, mesh)
    corpus = bt.device_corpus(shardings=csh, batch_shardings=bsh)
    idx = bt.rounds_indices(K)
    src = corpus.source(idx)

    # -- gather preserves batch-leaf shardings inside the jit --
    g = jax.jit(lambda s: s.gather(s.idx[0]))(src)
    shard_ok = all(
        leaf.sharding.is_equivalent_to(
            NamedSharding(mesh, batch_pspec(mesh, True, leaf.ndim)), leaf.ndim)
        for leaf in jax.tree.leaves(g)
    )
    corpus_replicated = all(
        l.sharding.is_fully_replicated for l in jax.tree.leaves(corpus.arrays)
    )

    # -- tree driver window vs per-round tree_round oracle, same plan --
    params = jax.device_put(M.init(jax.random.PRNGKey(0), cfg),
                            named(mesh, param_pspecs(
                                M.init(jax.random.PRNGKey(0), cfg), mesh)))
    plan = TrainPlan(W, QMAX, B)
    qs = StragglerModel(kind="shifted_exp").realize_steps_matrix(
        np.random.default_rng(1), K, W, 3.0, QMAX)
    eng = make_train_engine(cfg, plan, opt=sgd(1e-3))
    assert eng.layout == "tree"
    st, outs = eng.run(eng.init_state(params, ()), src, qs, keep_history=True)

    oracle = make_train_engine(cfg, plan, opt=sgd(1e-3))
    rnd = jax.jit(oracle.tree_round())  # the legacy per-round dispatch
    p, o = params, ()
    hidx = np.asarray(idx)
    max_d = 0.0
    for k in range(K):
        mb = jax.device_put(
            {kk: jnp.asarray(v[hidx[k]]) for kk, v in bt.inner.arrays.items()},
            bsh)
        p, o, m = rnd(p, o, mb, jnp.asarray(qs[k], jnp.int32),
                      jnp.asarray(k * QMAX))
        d = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))) if a.size else 0.0,
            jax.tree.map(lambda l: l[k], outs["arena"]), p)
        max_d = max([max_d] + jax.tree.leaves(d))
    driver_sharded = all(
        not l.sharding.is_fully_replicated
        for l in jax.tree.leaves(st.arena) if l.ndim >= 2 and l.size >= 64
    )

    # -- the trainer end to end: whole budget, ONE dispatch --
    from repro.launch.train import main
    buf = io.StringIO()
    with redirect_stdout(buf):
        loss = main(["--arch", "qwen2-0.5b", "--reduced", "--rounds", "4",
                     "--workers", "2", "--q-max", "2", "--seq-len", "32",
                     "--local-batch", "2", "--n-seqs", "64",
                     "--model-parallel", "2", "--log-every", "100"])
    out = buf.getvalue()
    print(json.dumps({
        "shard_ok": shard_ok,
        "corpus_replicated": corpus_replicated,
        "max_driver_vs_oracle": max_d,
        "driver_params_stay_sharded": driver_sharded,
        "train_loss": float(loss),
        "train_one_dispatch": "jit dispatches: 1" in out,
        "train_layout_tree": "layout=tree" in out,
    }))
    """
)


@pytest.mark.slow
def test_tree_driver_model_parallel_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["shard_ok"], out
    assert out["corpus_replicated"], out
    assert out["max_driver_vs_oracle"] == 0.0, out
    assert out["driver_params_stay_sharded"], out
    assert out["train_one_dispatch"] and out["train_layout_tree"], out
    assert out["train_loss"] == out["train_loss"]  # finite (not NaN)
