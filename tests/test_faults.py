"""FaultSpec schedule language: grammar round-trip, seeded determinism,
per-worker slicing, and validation (core/faults.py)."""
import numpy as np
import pytest

from repro.core.faults import FaultEvent, FaultSpec, matrix_spec


def test_parse_roundtrip():
    text = "kill@3:1,hang@5:0:2.5,slow@2:2:0.04,drop@7:1,delay@9:0:0.8"
    spec = FaultSpec.parse(text)
    assert len(spec.events) == 5
    # events sort by (round, worker, kind); str() round-trips the set
    assert FaultSpec.parse(str(spec)) == spec
    kinds = {e.kind for e in spec.events}
    assert kinds == {"kill", "hang", "slow", "drop", "delay"}


def test_parse_empty_and_whitespace():
    assert not FaultSpec.parse(None)
    assert not FaultSpec.parse("")
    assert not FaultSpec.parse("  ,  ")


@pytest.mark.parametrize("bad", [
    "explode@1:0",          # unknown kind
    "kill@x:0",             # non-int round
    "kill@1",               # missing worker
    "hang@1:0",             # hang needs :arg seconds
    "slow@2:1",             # slow needs :arg seconds
    "kill@-1:0",            # negative round
])
def test_parse_rejects_bad_tokens(bad):
    with pytest.raises(ValueError):
        FaultSpec.parse(bad)


def test_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(1, 0, "nope")
    with pytest.raises(ValueError):
        FaultEvent(1, 0, "hang", -1.0)


def test_for_worker_plain_containers():
    spec = FaultSpec.parse("kill@3:1,hang@3:0:2.0,drop@5:1")
    w1 = spec.for_worker(1)
    assert w1 == {3: [("kill", 0.0)], 5: [("drop", 0.0)]}
    assert spec.for_worker(0) == {3: [("hang", 2.0)]}
    assert spec.for_worker(9) == {}


def test_seeded_is_deterministic_and_kill_terminal():
    a = FaultSpec.seeded(7, 50, 4, p_kill=0.05, p_hang=0.1, p_drop=0.1)
    b = FaultSpec.seeded(7, 50, 4, p_kill=0.05, p_hang=0.1, p_drop=0.1)
    assert a == b and str(a) == str(b)
    assert a != FaultSpec.seeded(8, 50, 4, p_kill=0.05, p_hang=0.1, p_drop=0.1)
    # a killed worker draws no further events
    for w in range(4):
        evs = sorted(e for e in a.events if e.worker == w)
        kills = [e for e in evs if e.kind == "kill"]
        if kills:
            assert evs[-1] == kills[0], evs


def test_seeded_validation():
    with pytest.raises(ValueError):
        FaultSpec.seeded(0, 0, 4)
    with pytest.raises(ValueError):
        FaultSpec.seeded(0, 10, 4, p_kill=1.5)


def test_matrix_spec_and_views():
    spec = matrix_spec([3, 6, 9], [0, 1, 2], ["kill", "hang", "drop"], hang=2.0)
    assert spec.rounds_hit() == {"kill": [3], "hang": [6], "drop": [9]}
    assert spec.for_worker(1) == {6: [("hang", 2.0)]}
    merged = spec.merged(FaultSpec.parse("slow@1:0:0.1"))
    assert len(merged.events) == 4 and merged.events[0].kind == "slow"
