"""Convergence-analysis bound evaluators (paper Sec. III) + empirical checks."""
import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnytimeConfig, anytime_round, anytime_lambdas
from repro.core.theory import (
    ProblemConstants,
    cor4_variance_bound,
    optimal_lambdas_minimize_thm2,
    step_size_beta,
    thm1_expected_distance,
    thm2_variance_bound,
    thm5_high_prob_bound,
)
from repro.data.linreg import make_linreg
from repro.optim import sgd
from repro.optim.schedules import anytime_paper_schedule

C = ProblemConstants(lipschitz_l=10.0, sigma=2.0, diameter_d=5.0, grad_bound_g=8.0)


def test_step_size_thm1_form():
    beta = step_size_beta(np.arange(4), C)
    np.testing.assert_allclose(beta, np.sqrt(np.arange(4) + 1) * C.sigma / C.diameter_d)
    sched = anytime_paper_schedule(C.lipschitz_l, C.sigma, C.diameter_d)
    assert float(sched(0)) == pytest.approx(1.0 / (C.lipschitz_l + C.sigma / C.diameter_d))


@hypothesis.given(
    q=hnp.arrays(np.int64, st.integers(1, 16), elements=st.integers(0, 500)).filter(
        lambda q: q.sum() > 0
    )
)
def test_thm2_bound_minimized_by_thm3_weights(q):
    """Any other simplex point gives a >= variance bound (Thm 3 optimality)."""
    lam_star = optimal_lambdas_minimize_thm2(q)
    v_star = thm2_variance_bound(q, lam_star, C)
    rng = np.random.default_rng(0)
    for _ in range(5):
        lam = rng.random(len(q))
        lam = np.where(q > 0, lam, 0.0)
        if lam.sum() == 0:
            continue
        lam /= lam.sum()
        assert v_star <= thm2_variance_bound(q, lam, C) + 1e-9


def test_cor4_equals_thm2_at_optimum():
    q = np.array([10, 5, 0, 25])
    lam = np.asarray(anytime_lambdas(jnp.asarray(q)))
    np.testing.assert_allclose(
        thm2_variance_bound(q, lam, C), cor4_variance_bound(q, C), rtol=1e-6
    )


def test_cor4_inverse_q_decay():
    """Variance bound ~ 1/Q (Corollary 4)."""
    v1 = cor4_variance_bound(np.array([10, 10]), C)
    v2 = cor4_variance_bound(np.array([20, 20]), C)
    assert v2 == pytest.approx(v1 / 2)


def test_thm1_and_thm5_finite_positive():
    q = np.array([8, 4, 0, 2])
    lam = np.asarray(anytime_lambdas(jnp.asarray(q)))
    assert thm1_expected_distance(q, lam, f0_gap=3.0, c=C) > 0
    b = thm5_high_prob_bound(q, lam, delta=0.05, c=C)
    assert np.isfinite(b) and b > 0
    # tighter delta -> larger bound
    assert thm5_high_prob_bound(q, lam, 0.01, C) > b


@pytest.mark.slow
def test_empirical_variance_decays_with_q(rng):
    """Cor 4 qualitatively: at FIXED per-worker work q, quadrupling the
    worker count quadruples Q = W*q and must shrink the run-to-run variance
    of F(x)-F(x*) after one round (expected progress is comparable, so the
    raw variances are directly comparable)."""
    lin = make_linreg(4000, 10, seed=0)
    fstar = float(np.mean((lin.A @ lin.x_star - lin.y) ** 2))
    qmax = 8

    def one_round_gap(w, seed):
        cfg = AnytimeConfig(n_workers=w, max_local_steps=qmax)
        rnd = jax.jit(anytime_round(
            lambda p, mb: jnp.mean((mb[0] @ p["x"] - mb[1]) ** 2), sgd(0.01), cfg))
        r = np.random.default_rng(seed)
        idx = r.integers(0, lin.m, size=(w, qmax, 4))
        batch = (jnp.asarray(lin.A[idx], jnp.float32), jnp.asarray(lin.y[idx], jnp.float32))
        q = jnp.full((w,), qmax, jnp.int32)
        p, _, _ = rnd({"x": jnp.zeros(10, jnp.float32)}, (), batch, q)
        x = np.asarray(p["x"], np.float64)
        return float(np.mean((lin.A @ x - lin.y) ** 2)) - fstar

    gaps_small = [one_round_gap(2, s) for s in range(16)]
    gaps_big = [one_round_gap(8, s) for s in range(16)]
    assert np.var(gaps_big) < np.var(gaps_small), (np.var(gaps_big), np.var(gaps_small))
