"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
assigned family — one forward/train step + one decode step on CPU,
asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.models.kvcache import init_cache, resolve_heads

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_prefix_embeddings or cfg.family == "encdec":
        p = cfg.n_prefix_embeddings or 8
        batch["prefix_embeddings"] = jnp.ones((b, p, cfg.prefix_source_dim or cfg.d_model), cfg.dtype_)
    return batch


@pytest.fixture(scope="module")
def reduced_params():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            cache[arch] = (cfg, M.init(KEY, cfg))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 3 and r.d_model <= 512
    if r.moe:
        assert r.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch, reduced_params):
    cfg, params = reduced_params(arch)
    batch = _batch(cfg)
    logits, aux = M.apply(params, cfg, batch["tokens"], batch.get("prefix_embeddings"))
    assert logits.shape == (2, 16, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = M.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch, reduced_params):
    """One SGD step on one batch must reduce that batch's loss."""
    cfg, params = reduced_params(arch)
    batch = _batch(cfg)
    loss0, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    loss1 = M.loss_fn(params2, cfg, batch)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, reduced_params):
    cfg, params = reduced_params(arch)
    cache = init_cache(cfg, 2, 32)
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    logits, cache2 = M.decode_step(params, cfg, cache, tok, jnp.int32(0))
    assert logits.shape == (2, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab].astype(jnp.float32))))
    # padded vocab entries are masked to -inf-ish
    if cfg.padded_vocab() > cfg.vocab:
        assert float(logits[0, cfg.vocab]) < -1e29
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2))
    )
    assert changed


def test_head_padding_is_inert():
    """Padded q heads contribute nothing and get no wo gradient."""
    r = dataclasses.replace(get_config("qwen2_0_5b").reduced(), dtype="float32")
    c16 = dataclasses.replace(r, model_parallel=16)
    params = M.init(KEY, c16)
    batch = _batch(c16)
    _, grads = jax.value_and_grad(lambda p: M.loss_fn(p, c16, batch))(params)
    hp, _, _ = resolve_heads(c16)
    hd = c16.head_dim_
    pad_rows = grads["blocks"]["attn"]["wo"][:, c16.n_heads * hd :, :]
    assert float(jnp.abs(pad_rows).max()) == 0.0


def test_param_count_matches_eval_shape():
    cfg = get_config("qwen2_0_5b").reduced()
    n = M.param_count(cfg)
    params = M.init(KEY, cfg)
    n_real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    assert n == n_real


def test_moe_aux_losses_present():
    cfg, params = get_config("phi3_5_moe_42b").reduced(), None
    params = M.init(KEY, cfg)
    batch = _batch(cfg)
    _, aux = M.apply(params, cfg, batch["tokens"])
    assert float(aux["moe_aux"]) > 0
    assert 0.0 <= float(aux["moe_dropped"]) <= 1.0
