"""Decode-vs-parallel consistency: teacher-forced decode through the cache
must reproduce apply()'s logits (the strongest correctness check the serve
path has)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models.kvcache import init_cache
from repro.models import ssm as ssm_mod

KEY = jax.random.PRNGKey(0)


def _decode_all(params, cfg, tokens, cap):
    cache = init_cache(cfg, tokens.shape[0], cap)
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = M.decode_step(params, cfg, cache, tokens[:, t][:, None], jnp.int32(t))
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # [B, S, Vp]


@pytest.mark.parametrize(
    "arch", ["qwen2_0_5b", "minicpm3_4b", "starcoder2_7b", "xlstm_350m", "hymba_1_5b"]
)
def test_decode_matches_apply(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = M.init(KEY, cfg)
    b, s = 2, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    par, _ = M.apply(params, cfg, toks)
    seq = _decode_all(params, cfg, toks, cap=max(s, cfg.sliding_window if cfg.attn == "sliding" else s))
    np.testing.assert_allclose(
        np.asarray(seq[:, :, : cfg.vocab]), np.asarray(par[:, :, : cfg.vocab]),
        rtol=2e-2, atol=2e-2,
    )


def test_sliding_ring_matches_full_for_short_seq():
    """While seq <= window the ring cache must equal full attention."""
    cfg = dataclasses.replace(
        get_config("llava_next_mistral_7b").reduced(),
        dtype="float32", n_prefix_embeddings=0, family="dense",
    )
    assert cfg.attn == "sliding"
    params = M.init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 10), 0, cfg.vocab)
    par, _ = M.apply(params, cfg, toks)  # sliding mask, seq 10 < window 64
    seq = _decode_all(params, cfg, toks, cap=cfg.sliding_window)
    np.testing.assert_allclose(
        np.asarray(seq[:, :, : cfg.vocab]), np.asarray(par[:, :, : cfg.vocab]),
        rtol=2e-2, atol=2e-2,
    )


def test_mlstm_step_matches_parallel():
    """mLSTM O(1) recurrence == quadratic parallel training form."""
    rng = np.random.default_rng(0)
    b, s, h, dh = 2, 24, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    ig = jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32)
    fg = jnp.asarray(rng.standard_normal((b, s, h)) + 2.0, jnp.float32)
    par = ssm_mod.mlstm_parallel(q, k, v, ig, fg)
    st = {
        "c": jnp.zeros((b, h, dh, dh), jnp.float32),
        "n": jnp.zeros((b, h, dh), jnp.float32),
        "m": jnp.zeros((b, h), jnp.float32),
    }
    outs = []
    for t in range(s):
        o, st = ssm_mod.mlstm_step(q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t], st)
        outs.append(o)
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(par), rtol=2e-3, atol=2e-3)


def test_mamba_state_continuation():
    """mamba_mixer decode state must continue the training-form scan."""
    import repro.models.ssm as S
    rng = np.random.default_rng(1)
    b, s, di, n = 1, 20, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, di)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, di)) * 0.1 + 1e-3, jnp.float32)
    a = -jnp.asarray(rng.random((di, n)) + 0.2, jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    d = jnp.zeros(di, jnp.float32)
    y_all, h_all = S.selective_scan_ref(x, dt, a, bb, cc, d)
    # two halves with carried state
    y1, h1 = S.selective_scan_ref(x[:, :10], dt[:, :10], a, bb[:, :10], cc[:, :10], d)
    y2, h2 = S.selective_scan_ref(x[:, 10:], dt[:, 10:], a, bb[:, 10:], cc[:, 10:], d, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_all), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all), rtol=1e-4, atol=1e-5)


def test_serve_driver_runs():
    from repro.launch.serve import main
    gen = main(["--arch", "qwen2-0.5b", "--reduced", "--batch", "2",
                "--prompt-len", "4", "--gen", "4"])
    assert gen.shape == (2, 4)
