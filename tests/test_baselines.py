"""Baseline schemes + the paper's comparative claims (Sec. II-E, IV)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnytimeConfig, anytime_round
from repro.core.baselines import (
    fnb_epoch_time,
    fnb_round,
    gc_epoch_time,
    make_cyclic_code,
    sync_epoch_time,
    sync_round,
)
from repro.core.baselines.fnb import fastest_mask
from repro.core.straggler import StragglerModel
from repro.data.linreg import make_linreg
from repro.optim import sgd


def _loss(params, mb):
    a, y = mb
    r = a @ params["x"] - y
    return jnp.mean(r * r)


def _batch(data, rng, w, q, b, pools=None):
    if pools is None:
        idx = rng.integers(0, data.m, size=(w, q, b))
    else:
        idx = np.stack([rng.choice(pools[v], size=(q, b)) for v in range(w)])
    return (jnp.asarray(data.A[idx], jnp.float32), jnp.asarray(data.y[idx], jnp.float32))


def test_sync_round_uniform_average(rng):
    lin = make_linreg(500, 8, seed=0)
    rnd = sync_round(_loss, sgd(0.01), n_workers=4, k_steps=3)
    params = {"x": jnp.zeros(8, jnp.float32)}
    p, _, m = rnd(params, (), _batch(lin, rng, 4, 3, 8))
    np.testing.assert_allclose(np.asarray(m["lambdas"]), 0.25, atol=1e-6)
    assert np.all(np.isfinite(np.asarray(p["x"])))


def test_fnb_discards_slow_workers(rng):
    lin = make_linreg(500, 8, seed=0)
    rnd = fnb_round(_loss, sgd(0.01), n_workers=4, k_steps=3)
    params = {"x": jnp.zeros(8, jnp.float32)}
    mask = jnp.asarray([True, True, False, False])
    p, _, m = rnd(params, (), _batch(lin, rng, 4, 3, 8), mask)
    lam = np.asarray(m["lambdas"])
    np.testing.assert_allclose(lam, [0.5, 0.5, 0, 0], atol=1e-6)


def test_fastest_mask_excludes_persistent():
    finish = np.array([3.0, 1.0, np.inf, 2.0])
    mask = fastest_mask(finish, n_drop=1)
    assert mask.tolist() == [True, True, False, True]
    mask0 = fastest_mask(finish, n_drop=0)  # inf can never be "kept"
    assert mask0.tolist() == [True, True, False, True]


def test_epoch_time_ordering(rng):
    """Wall-clock per epoch: FNB <= GC(N-S wait) <= Sync, given one model."""
    m = StragglerModel(kind="shifted_exp", rate=0.5)
    r1, r2, r3 = (np.random.default_rng(5) for _ in range(3))
    t_sync = sync_epoch_time(m, r1, 10, k_steps=30)
    t_fnb, _ = fnb_epoch_time(m, r2, 10, k_steps=30, n_drop=2)
    t_gc, _ = gc_epoch_time(m, r3, 10, s=2, steps_per_block=10)
    assert t_fnb < t_sync
    assert t_gc <= sync_epoch_time(m, np.random.default_rng(5), 10, k_steps=30)


def test_sync_stalls_with_persistent_straggler(rng):
    m = StragglerModel(persistent_frac=0.1)
    assert np.isinf(sync_epoch_time(m, rng, 10, k_steps=5))
    t_fnb, mask = fnb_epoch_time(m, rng, 10, k_steps=5, n_drop=1)
    assert np.isfinite(t_fnb) and not mask[-1]


def test_fnb_persistent_bias_vs_anytime_robustness(rng):
    """[Tandon] Fig 7 / paper Sec II-E: FNB with a persistent straggler and
    S=0 permanently loses that worker's data -> biased solution; Anytime
    with S=1 replication reaches the optimum."""
    from repro.core.assignment import worker_sample_ids

    lin = make_linreg(1200, 10, seed=4)
    w, qmax = 6, 6
    # make block 5's data essential: shift its labels strongly
    lin.A[1000:, :] *= 3.0
    lin.y[:] = lin.A @ lin.x_star
    dead = 5  # persistent straggler

    # FNB S=0: worker v samples only its own block
    pools0 = [worker_sample_ids(v, lin.m, w, 0) for v in range(w)]
    rnd = fnb_round(_loss, sgd(0.02), w, qmax)
    params = {"x": jnp.zeros(10, jnp.float32)}
    mask = jnp.asarray([v != dead for v in range(w)])
    for _ in range(30):
        params, _, _ = rnd(params, (), _batch(lin, rng, w, qmax, 16, pools0), mask)
    err_fnb = lin.normalized_error(np.asarray(params["x"], np.float64))

    # Anytime S=1: replicated blocks keep coverage
    pools1 = [worker_sample_ids(v, lin.m, w, 1) for v in range(w)]
    cfg = AnytimeConfig(n_workers=w, max_local_steps=qmax)
    arnd = anytime_round(_loss, sgd(0.02), cfg)
    params = {"x": jnp.zeros(10, jnp.float32)}
    q = jnp.asarray([qmax] * w, jnp.int32).at[dead].set(0)
    for _ in range(30):
        params, _, _ = arnd(params, (), _batch(lin, rng, w, qmax, 16, pools1), q)
    err_any = lin.normalized_error(np.asarray(params["x"], np.float64))
    assert err_any < err_fnb, (err_any, err_fnb)
    assert err_any < 0.12


def test_gc_code_reusable_across_epochs():
    code = make_cyclic_code(10, 2, seed=0)
    assert code.n_wait == 8
