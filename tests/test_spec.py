"""Deadline-adaptive speculative decoding (ISSUE 10, DESIGN.md §14):
multi-query verification kernel parity, rejection-sampling exactness, the
n-gram drafter, paged-KV rewind under speculation, and the greedy pin —
speculative output must be token-for-token identical to the plain paged
scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import decode_attention
from repro.kernels.paged_decode_attention import (
    paged_verify_attention,
    paged_verify_ref,
)
from repro.launch import sampling as S
from repro.launch.scheduler import NGramDrafter, PagedScheduler, Request, _Seq
from repro.models import model as M


# ==========================================================================
# Multi-query verification kernel
# ==========================================================================
def _verify_case(seed=0, nb=10, bs=8, b=3, t=4, h=8, hkv=2, dh=16,
                 dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, dh), dtype)
    k_pool = jax.random.normal(ks[1], (nb, bs, hkv, dh), dtype)
    v_pool = jax.random.normal(ks[2], (nb, bs, hkv, dh), dtype)
    # permuted physical blocks; logical order only exists in the table
    tables = jnp.asarray([[3, 7, 1], [5, 2, 8], [9, 4, 6]], jnp.int32)
    # row 0: full window at a deep base; row 1: ragged (2 of 4 queries
    # live); row 2: idle (n_q = 0, base -1 like a padded scheduler row)
    base = jnp.asarray([20, 10, -1], jnp.int32)
    n_q = jnp.asarray([4, 2, 0], jnp.int32)
    qmap = jnp.asarray([i // (h // hkv) for i in range(h)], jnp.int32)
    return q, k_pool, v_pool, tables, base, n_q, qmap


def test_verify_kernel_matches_oracle():
    q, kp, vp, tbl, base, n_q, qmap = _verify_case()
    out = paged_verify_attention(q, kp, vp, tbl, base, n_q, qmap, interpret=True)
    ref = paged_verify_ref(q, kp, vp, tbl, base, n_q, qmap)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    # dead query rows and the idle sequence are exactly zero
    np.testing.assert_array_equal(np.asarray(out[1, 2:]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[2]), 0.0)


def test_verify_kernel_matches_dense_kernel():
    """Each query position j attends over [0, base+j] — gather the pool
    through the (permuted) table into the dense rectangle and the dense
    decode kernel must agree position by position."""
    q, kp, vp, tbl, base, n_q, qmap = _verify_case()
    b, t, h, dh = q.shape
    bs = kp.shape[1]
    c = tbl.shape[1] * bs
    k = jnp.take(kp, tbl.reshape(-1), axis=0).reshape(b, c, -1, dh)
    v = jnp.take(vp, tbl.reshape(-1), axis=0).reshape(b, c, -1, dh)
    k = jnp.take(k, qmap, axis=2)
    v = jnp.take(v, qmap, axis=2)
    out = paged_verify_attention(q, kp, vp, tbl, base, n_q, qmap, interpret=True)
    for j in range(t):
        valid = jnp.arange(c)[None, :] <= (base + j)[:, None]
        dense = decode_attention(q[:, j], k, v, valid, bk=8, interpret=True)
        live = np.asarray(n_q) > j
        np.testing.assert_allclose(
            np.asarray(out[:, j])[live], np.asarray(dense)[live],
            rtol=1e-5, atol=1e-5,
        )


def test_verify_kernel_t1_matches_decode_semantics():
    """A T=1 verify window is exactly a decode step with seq_len base+1."""
    from repro.kernels.paged_decode_attention import paged_decode_attention
    q, kp, vp, tbl, base, n_q, qmap = _verify_case(t=1)
    n_q = jnp.minimum(n_q, 1)
    out = paged_verify_attention(q, kp, vp, tbl, base, n_q, qmap, interpret=True)
    lens = jnp.where(n_q > 0, base + 1, 0)
    dec = paged_decode_attention(q[:, 0], kp, vp, tbl, lens, qmap, interpret=True)
    live = np.asarray(n_q) > 0
    np.testing.assert_allclose(
        np.asarray(out[:, 0])[live], np.asarray(dec)[live], rtol=1e-5, atol=1e-5)


# ==========================================================================
# Sampling + speculative rejection sampling
# ==========================================================================
def test_probs_filters():
    logits = np.array([3.0, 2.0, 1.0, 0.0])
    p = S.probs(logits, S.SamplingParams(temperature=1.0))
    np.testing.assert_allclose(p.sum(), 1.0)
    assert np.all(np.diff(p) < 0)  # monotone in logits
    pk = S.probs(logits, S.SamplingParams(temperature=1.0, top_k=2))
    assert pk[2] == 0.0 and pk[3] == 0.0 and pk[0] > 0 and pk[1] > 0
    pp = S.probs(logits, S.SamplingParams(temperature=1.0, top_p=0.6))
    assert pp[0] > 0 and pp[3] == 0.0  # nucleus keeps the smallest cover


def test_spec_accept_greedy_is_argmax_equality():
    logits = np.array([0.0, 5.0, 1.0])
    sp = S.SamplingParams()  # greedy
    rng = np.random.default_rng(0)
    ok, tok = S.spec_accept(1, logits, sp, rng)
    assert ok and tok == 1
    ok, tok = S.spec_accept(0, logits, sp, rng)
    assert not ok and tok == 1  # correction is the argmax


def test_spec_accept_distribution_exact():
    """With a deterministic drafter, accept-or-resample must emit tokens
    distributed EXACTLY as the target distribution, for every draft
    choice — the Leviathan identity specialized to q = delta_d."""
    rng0 = np.random.default_rng(0)
    logits = rng0.standard_normal(8) * 2.0
    sp = S.SamplingParams(temperature=0.7, top_k=6)
    p = S.probs(logits, sp)
    n = 20_000
    for draft in (int(np.argmax(p)), int(np.argmin(p)), 3):
        rng = np.random.default_rng(draft + 1)
        counts = np.zeros(8)
        for _ in range(n):
            _, tok = S.spec_accept(draft, logits, sp, rng)
            counts[tok] += 1
        np.testing.assert_allclose(counts / n, p, atol=4.5 * np.sqrt(0.25 / n))


def test_seq_rng_reproducible_and_independent():
    a = S.seq_rng(1, 2).random(4)
    b = S.seq_rng(1, 2).random(4)
    c = S.seq_rng(1, 3).random(4)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


# ==========================================================================
# N-gram drafter
# ==========================================================================
def test_drafter_prompt_lookup():
    d = NGramDrafter()
    h = np.array([1, 2, 3, 4, 1, 2, 3], np.int32)
    assert d.draft(h, 4) == [4, 1, 2, 3]  # trigram [1,2,3] continues with 4...
    assert d.draft(h, 2) == [4, 1]  # ...truncated to k


def test_drafter_prefers_most_recent_match():
    d = NGramDrafter()
    h = np.array([1, 2, 9, 5, 1, 2, 7, 5, 1, 2], np.int32)
    assert d.draft(h, 1) == [7]  # bigram [1,2] last seen at index 4, not 0


def test_drafter_backs_off_to_shorter_ngrams():
    h = np.array([9, 8, 7, 3, 6, 5, 3], np.int32)
    # opt-in unigram backoff: no tri/bigram repeat; unigram 3 -> [6, 5]
    assert NGramDrafter(min_n=1).draft(h, 2) == [6, 5]
    # the default demands bigram evidence — a lone repeated token is noise
    assert NGramDrafter().draft(h, 2) == []


def test_drafter_no_match_returns_empty():
    d = NGramDrafter()
    assert d.draft(np.array([1, 2, 3, 4], np.int32), 3) == []
    assert d.draft(np.array([5], np.int32), 3) == []
    assert d.draft(np.array([1, 1, 2], np.int32), 0) == []


# ==========================================================================
# Anytime k_v adaptation (budget rule + reservation cap)
# ==========================================================================
def _mk_sched(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("n_blocks", 64)
    kw.setdefault("block_size", 4)
    kw.setdefault("chunk_tokens", 8)
    kw.setdefault("deadline_ms", 1e9)
    kw.setdefault("spec", True)
    return PagedScheduler(cfg, params, **kw)


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(get_config("qwen2_0_5b").reduced(), dtype="float32")
    return cfg, M.init(jax.random.PRNGKey(0), cfg)


def test_k_budget_rule(qwen):
    cfg, params = qwen
    sch = _mk_sched(cfg, params)
    assert sch._k_budget(1.0) == 0  # cold: no base-cost estimate yet
    sch._t_base = 0.010
    assert sch._k_budget(0.005) == 0  # budget below one base step
    assert sch._k_budget(0.025) == 1  # no marginal estimate: probe one token
    sch._t_tok = 0.002
    # window cost = 7 * 2ms: all-or-nothing — 16ms budget leaves only 6ms
    assert sch._k_budget(0.016) == 0
    assert sch._k_budget(0.030) == sch.spec_max_k  # 0.9*20ms covers 14ms
    assert sch._k_budget(1.0) == sch.spec_max_k
    assert sch._k_budget(-0.001) == 0  # deadline already blown -> plain tick
    sch.spec = False
    assert sch._k_budget(1.0) == 0


def test_draft_len_respects_reservation_and_ema(qwen):
    cfg, params = qwen
    sch = _mk_sched(cfg, params)
    sb = sch.bm.admit_prompt(list(range(8)), max_new=4)
    sq = _Seq(rid=0, prompt=np.arange(8, dtype=np.int32), max_new=4, sb=sb,
              prefilled=8, out=[7, 5], last_tok=6, n_ctx=10)
    sch._rngs[0] = S.seq_rng(0, 0)
    # reservation cap: max_new - len(out) - 1 = 1, regardless of budget k
    assert len(sch._draft_for(sq, 8)) <= 1
    sq.out = [7, 5, 6]
    assert sch._draft_for(sq, 8) == []  # last token: never draft past max_new-1
    # a collapsed acceptance EMA shuts drafting off until the probe clock
    sq.out = []
    sq.n_ctx = 8
    sq.accept_ema = 0.0
    sq.since_spec = 0
    assert sch._draft_for(sq, 8) == []
    sq.since_spec = 32
    sq.prompt = np.array([1, 2, 3, 1, 2], np.int32)  # drafter has material
    sq.last_tok = 3
    assert len(sch._draft_for(sq, 8)) == 1  # probe reopens speculation


def test_zero_deadline_keeps_no_stall_pin(qwen):
    """deadline 0 with speculation enabled == the PR 8 strict schedule:
    decode + exactly one prefill chunk per tick, k_v pinned to 0."""
    cfg, params = qwen
    rng = np.random.default_rng(3)
    sch = _mk_sched(cfg, params, deadline_ms=0.0)
    sch.submit(Request(0, rng.integers(0, cfg.vocab, 5).astype(np.int32), 12))
    for _ in range(3):
        sch.tick()
    n0 = len(sch.active[0].out)
    assert n0 == 2
    sch.submit(Request(1, rng.integers(0, cfg.vocab, 40).astype(np.int32), 3))
    for k in range(1, 5):
        sch.tick()
        assert len(sch.active[0].out) == n0 + k  # one token every tick
        assert not sch.active[1].decoding
    sch.run_to_completion()
    assert sch.spec_drafted == 0  # zero budget -> speculation never ran


# ==========================================================================
# Greedy pin: speculative == plain paged scheduler, token for token
# ==========================================================================
def _run_sched(cfg, params, spec, sampling=S.SamplingParams(), seed=0):
    sch = _mk_sched(cfg, params, spec=spec, sampling=sampling, seed=seed)
    rng = np.random.default_rng(0)
    motif = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    for rid in range(3):
        prompt = np.tile(motif, 8)[: 14 + 5 * rid]
        sch.submit(Request(rid, prompt, 10))
    got = sch.run_to_completion()
    return got, sch


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "minicpm3_4b"])
def test_greedy_speculation_matches_plain(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = M.init(jax.random.PRNGKey(0), cfg)
    plain, _ = _run_sched(cfg, params, spec=False)
    spec, sch = _run_sched(cfg, params, spec=True)
    assert spec == plain
    st = sch.stats()
    assert st["spec_drafted"] > 0 and st["spec_accepted"] > 0
    assert st["live"] == 0  # every block reclaimed after rewinds + retires
    assert st["free"] + st["cached"] == sch.bm.n_blocks - 1


def test_sampled_speculation_deterministic_and_complete(qwen):
    """Non-greedy speculation: same seed -> identical outputs; every
    sequence reaches exactly max_new tokens despite rewinds."""
    cfg, params = qwen
    sp = S.SamplingParams(temperature=1.0)
    a, sa = _run_sched(cfg, params, spec=True, sampling=sp, seed=11)
    b, _ = _run_sched(cfg, params, spec=True, sampling=sp, seed=11)
    assert a == b
    assert all(len(v) == 10 for v in a.values())
    assert sa.stats()["live"] == 0
