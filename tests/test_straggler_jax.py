"""Device-side q sampling (core/straggler_jax.py) vs the numpy oracle.

jax and numpy use different bit generators, so the contract is
DISTRIBUTIONAL: means and tail quantiles of the realized step counts must
agree, and the structural rules (persistent ids, clipping, hetero speeds
fixed per experiment) must hold exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.straggler import StragglerModel
from repro.core import straggler_jax as sjx

KINDS = ["constant", "shifted_exp", "pareto", "bimodal"]


def _oracle_q(model, n_draws, n_workers, budget, max_steps, seed=0):
    rng = np.random.default_rng(seed)
    return model.realize_steps_matrix(rng, n_draws, n_workers, budget, max_steps)


@pytest.mark.parametrize("kind", KINDS)
def test_q_distribution_matches_numpy_oracle(kind):
    """Mean and upper-tail quantiles of q match the host StragglerModel."""
    model = StragglerModel(kind=kind, rate=1.0, alpha=2.5, p_slow=0.2)
    budget, qmax, w = 12.0, 24, 8
    dev = np.asarray(
        sjx.sample_steps_matrix(
            model, jax.random.PRNGKey(0), 4000, w, budget, qmax
        )
    ).ravel()
    ora = _oracle_q(model, 4000, w, budget, qmax).ravel()
    assert dev.min() >= 0 and dev.max() <= qmax
    np.testing.assert_allclose(dev.mean(), ora.mean(), rtol=0.05)
    for pct in (50, 90, 99):
        d, o = np.percentile(dev, pct), np.percentile(ora, pct)
        assert abs(d - o) <= max(1.0, 0.05 * o), (pct, d, o)


def test_persistent_ids_deterministic_and_zero():
    """The last ceil(frac*W) workers never step — same id rule as numpy."""
    model = StragglerModel(kind="shifted_exp", persistent_frac=0.25)
    w = 10
    k = model.n_persistent(w)
    q = np.asarray(
        sjx.sample_steps_tensor(model, jax.random.PRNGKey(1), 6, 20, w, 50.0, 30)
    )
    assert q.shape == (6, 20, w)
    assert np.all(q[..., w - k :] == 0)
    assert np.all(q[..., : w - k].mean(axis=(1, 2)) > 0)


def test_hetero_speed_fixed_per_experiment():
    """worker_speed in [1, 1+spread]; constant-kind q depends only on the
    per-experiment speed, so it must be identical across rounds."""
    model = StragglerModel(kind="constant", hetero_spread=2.0)
    s = np.asarray(sjx.sample_worker_speed(model, jax.random.PRNGKey(2), 64))
    assert np.all(s >= 1.0) and np.all(s <= 3.0)
    q = np.asarray(
        sjx.sample_steps_tensor(model, jax.random.PRNGKey(3), 4, 8, 6, 20.0, 100)
    )
    # same fleet all rounds within an experiment...
    assert np.all(q == q[:, :1, :])
    # ...but a fresh fleet per experiment
    assert any(not np.array_equal(q[0], q[e]) for e in range(1, 4))


def test_budget_array_is_a_t_sweep():
    """[E] budgets: each experiment realizes its own T; q is monotone in T
    for the constant kind (same fleet, more time, never fewer steps)."""
    model = StragglerModel(kind="constant")
    budgets = jnp.asarray([2.0, 4.0, 8.0], jnp.float32)
    q = np.asarray(
        sjx.sample_steps_tensor(model, jax.random.PRNGKey(4), 3, 5, 4, budgets, 100)
    )
    assert q.shape == (3, 5, 4)
    np.testing.assert_array_equal(q[0], np.full((5, 4), 2))
    np.testing.assert_array_equal(q[2], np.full((5, 4), 8))


def test_max_steps_clip_and_jit():
    """The sampler jits cleanly (the whole grid draw is one dispatch) and
    respects the max_steps envelope."""
    model = StragglerModel(kind="pareto", alpha=1.1)
    f = jax.jit(
        lambda key: sjx.sample_steps_tensor(model, key, 8, 16, 10, 100.0, 24)
    )
    q = np.asarray(f(jax.random.PRNGKey(5)))
    assert q.shape == (8, 16, 10)
    assert q.min() >= 0 and q.max() <= 24
    # heavy-tail sanity: with T=100 and base 1s some workers hit the cap
    assert (q == 24).any()


def test_iter_times_persistent_inf():
    model = StragglerModel(kind="shifted_exp", persistent_frac=0.5)
    t = np.asarray(sjx.sample_iter_times(model, jax.random.PRNGKey(6), 4))
    assert np.isinf(t[2:]).all() and np.isfinite(t[:2]).all()


def test_unknown_kind_raises():
    model = StragglerModel(kind="constant")
    object.__setattr__(model, "kind", "bogus")
    with pytest.raises(ValueError):
        sjx.sample_steps_matrix(model, jax.random.PRNGKey(0), 2, 2, 1.0, 4)
