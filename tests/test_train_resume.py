"""Window-resumable training (ISSUE 4): a run killed between driver
windows must resume from its checkpoint with a BIT-identical loss
trajectory — EngineState (either layout) plus the data-plane index cursor
round-trip through CheckpointManager, and the host rng streams fast-forward
exactly (window-partition invariance, DESIGN.md §7/§8)."""
import json

import numpy as np
import pytest

from repro.launch.train import main

_BASE = ["--arch", "qwen2-0.5b", "--reduced", "--workers", "4", "--q-max", "2",
         "--seq-len", "32", "--local-batch", "2", "--n-seqs", "128",
         "--lr", "3e-3", "--log-every", "100"]


def _losses(path):
    with open(path) as f:
        return {r["round"]: r["loss"] for r in map(json.loads, f)}


@pytest.mark.parametrize("optimizer", ["sgd", "momentum"])
def test_killed_run_resumes_bit_identical(tmp_path, optimizer):
    """Stateless AND stateful resume: the momentum case pins that the
    optimizer moments round-trip through the checkpoint's opt arena — an
    f32 trajectory that continues bit-identically mid-window."""
    base = _BASE + ["--optimizer", optimizer]
    full_dir, part_dir = tmp_path / "full", tmp_path / "part"
    m_full, m_part = tmp_path / "full.jsonl", tmp_path / "part.jsonl"

    # reference: 8 uninterrupted rounds
    main(base + ["--rounds", "8", "--checkpoint-dir", str(full_dir),
                 "--metrics-file", str(m_full)])
    # "killed" run: stops after 4 rounds (checkpoint saved at round 4) ...
    main(base + ["--rounds", "4", "--checkpoint-dir", str(part_dir)])
    # ... then resumes to the full budget
    loss = main(base + ["--rounds", "8", "--checkpoint-dir", str(part_dir),
                        "--resume", "--metrics-file", str(m_part)])
    assert np.isfinite(loss)

    full, part = _losses(m_full), _losses(m_part)
    assert sorted(part) == [4, 5, 6, 7], part  # only the resumed tail ran
    for r in part:
        assert part[r] == full[r], (r, part[r], full[r])  # bitwise


_LAYOUTS = {
    "arena": [],
    "tree": ["--layout", "tree"],
}


@pytest.mark.parametrize("layout", sorted(_LAYOUTS), ids=sorted(_LAYOUTS))
def test_resume_with_no_checkpoint_starts_fresh(tmp_path, layout, capsys):
    """--resume against an EMPTY checkpoint dir starts fresh with a notice
    (never raises) — in both engine state layouts."""
    d = tmp_path / "empty"
    m = tmp_path / "m.jsonl"
    main(_BASE + _LAYOUTS[layout] + ["--optimizer", "sgd", "--rounds", "2",
                                     "--checkpoint-dir", str(d), "--resume",
                                     "--metrics-file", str(m)])
    assert sorted(_losses(m)) == [0, 1]
    assert "starting fresh" in capsys.readouterr().out


@pytest.mark.parametrize("layout", sorted(_LAYOUTS), ids=sorted(_LAYOUTS))
def test_resume_with_missing_dir_starts_fresh(tmp_path, layout, capsys):
    """--resume with a checkpoint dir that does not exist yet (first launch
    of a crash-looped job) is also a fresh run with a notice."""
    d = tmp_path / "never" / "created"
    m = tmp_path / "m.jsonl"
    main(_BASE + _LAYOUTS[layout] + ["--optimizer", "sgd", "--rounds", "2",
                                     "--checkpoint-dir", str(d), "--resume",
                                     "--metrics-file", str(m)])
    assert sorted(_losses(m)) == [0, 1]
    assert "starting fresh" in capsys.readouterr().out


def test_resume_without_ckpt_dir_notices(capsys):
    main(_BASE + ["--optimizer", "sgd", "--rounds", "1", "--resume"])
    assert "starting fresh" in capsys.readouterr().out


def test_resume_skips_corrupt_newest_checkpoint(tmp_path):
    """A checkpoint truncated by a mid-save kill must fall back to the
    previous complete save with a warning — the trajectory then continues
    from the older round instead of crashing (ISSUE 7 satellite)."""
    base = _BASE + ["--optimizer", "sgd"]
    d = tmp_path / "ckpt"
    m = tmp_path / "m.jsonl"
    # two saves: the 4-round run checkpoints at round 4, the resumed
    # 8-round run adds round 8 — leaving steps {4, 8} on disk
    main(base + ["--rounds", "4", "--checkpoint-dir", str(d)])
    main(base + ["--rounds", "8", "--checkpoint-dir", str(d), "--resume"])
    resume_dir = d / "resume"
    ckpts = sorted(resume_dir.glob("step_*.ckpt"))
    assert len(ckpts) >= 2, ckpts
    # truncate the NEWEST checkpoint: the torn-write state of a dead writer
    newest = ckpts[-1]
    newest.write_bytes(newest.read_bytes()[: 100])
    with pytest.warns(RuntimeWarning, match="skipping unreadable"):
        main(base + ["--rounds", "8", "--checkpoint-dir", str(d),
                     "--resume", "--metrics-file", str(m)])
    rounds = sorted(_losses(m))
    assert rounds and rounds[0] < 8 and rounds[-1] == 7, rounds
