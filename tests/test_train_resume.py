"""Window-resumable training (ISSUE 4): a run killed between driver
windows must resume from its checkpoint with a BIT-identical loss
trajectory — EngineState (either layout) plus the data-plane index cursor
round-trip through CheckpointManager, and the host rng streams fast-forward
exactly (window-partition invariance, DESIGN.md §7/§8)."""
import json

import numpy as np
import pytest

from repro.launch.train import main

_BASE = ["--arch", "qwen2-0.5b", "--reduced", "--workers", "4", "--q-max", "2",
         "--seq-len", "32", "--local-batch", "2", "--n-seqs", "128",
         "--lr", "3e-3", "--log-every", "100"]


def _losses(path):
    with open(path) as f:
        return {r["round"]: r["loss"] for r in map(json.loads, f)}


@pytest.mark.parametrize("optimizer", ["sgd", "momentum"])
def test_killed_run_resumes_bit_identical(tmp_path, optimizer):
    """Stateless AND stateful resume: the momentum case pins that the
    optimizer moments round-trip through the checkpoint's opt arena — an
    f32 trajectory that continues bit-identically mid-window."""
    base = _BASE + ["--optimizer", optimizer]
    full_dir, part_dir = tmp_path / "full", tmp_path / "part"
    m_full, m_part = tmp_path / "full.jsonl", tmp_path / "part.jsonl"

    # reference: 8 uninterrupted rounds
    main(base + ["--rounds", "8", "--checkpoint-dir", str(full_dir),
                 "--metrics-file", str(m_full)])
    # "killed" run: stops after 4 rounds (checkpoint saved at round 4) ...
    main(base + ["--rounds", "4", "--checkpoint-dir", str(part_dir)])
    # ... then resumes to the full budget
    loss = main(base + ["--rounds", "8", "--checkpoint-dir", str(part_dir),
                        "--resume", "--metrics-file", str(m_part)])
    assert np.isfinite(loss)

    full, part = _losses(m_full), _losses(m_part)
    assert sorted(part) == [4, 5, 6, 7], part  # only the resumed tail ran
    for r in part:
        assert part[r] == full[r], (r, part[r], full[r])  # bitwise


def test_resume_with_no_checkpoint_starts_fresh(tmp_path):
    d = tmp_path / "empty"
    m = tmp_path / "m.jsonl"
    main(_BASE + ["--optimizer", "sgd", "--rounds", "2",
                  "--checkpoint-dir", str(d), "--resume",
                  "--metrics-file", str(m)])
    assert sorted(_losses(m)) == [0, 1]
