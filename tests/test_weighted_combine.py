"""kernels/weighted_combine: padding, bf16-input/f32-accumulate, and
arena-combine equivalence (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arena as AR
from repro.core.combine import combine_pytrees
from repro.kernels import ops, ref
from repro.kernels.weighted_combine import weighted_combine


@pytest.mark.parametrize("n", [1, 100, 1023, 1024, 1025, 5000])
def test_padding_non_divisible_n(n):
    """N that does not divide block_n exercises the zero-pad + slice path;
    the pad lanes must contribute nothing."""
    rng = np.random.default_rng(0)
    w = 7
    x = jnp.asarray(rng.standard_normal((w, n)).astype(np.float32))
    lam = jnp.asarray(rng.random(w).astype(np.float32))
    out = weighted_combine(x, lam, block_n=1024, interpret=True)
    exp = ref.weighted_combine_ref(x, lam)
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)


def test_bf16_stack_f32_accumulate():
    """bf16 input stack: the reduction must run in f32 (an all-bf16
    accumulate of W=32 near-cancelling terms would visibly drift)."""
    rng = np.random.default_rng(1)
    w, n = 32, 700
    base = rng.standard_normal((w, n)).astype(np.float32)
    x_bf16 = jnp.asarray(base, jnp.bfloat16)
    lam = jnp.asarray(rng.random(w).astype(np.float32))
    out = weighted_combine(x_bf16, lam, block_n=256, interpret=True)
    assert out.dtype == jnp.float32
    # oracle: f32 contraction over the bf16-quantized inputs
    exp = ref.weighted_combine_ref(x_bf16.astype(jnp.float32), lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)


def test_bf16_out_dtype():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((4, 130)), jnp.bfloat16)
    lam = jnp.full((4,), 0.25, jnp.float32)
    out = weighted_combine(x, lam, block_n=64, interpret=True, out_dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    exp = ref.weighted_combine_ref(x.astype(jnp.float32), lam)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp), rtol=1e-2, atol=1e-2
    )


@pytest.mark.parametrize("scalar_prefetch", [True, False])
def test_lambda_scalar_prefetch_paths_agree(scalar_prefetch):
    """The PrefetchScalarGridSpec path (lam in SMEM, fetched once) and the
    interpret-safe plain-input fallback compute the same combine."""
    rng = np.random.default_rng(5)
    w, n = 9, 3000  # 3 grid steps at block_n=1024: lam reused across steps
    x = jnp.asarray(rng.standard_normal((w, n)).astype(np.float32))
    lam = jnp.asarray(rng.random(w).astype(np.float32))
    out = weighted_combine(x, lam, block_n=1024, interpret=True,
                           scalar_prefetch=scalar_prefetch)
    exp = ref.weighted_combine_ref(x, lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_arena_combine_matches_tree_combine():
    """ONE kernel call over the flat [W, N] arena == per-leaf tree-map."""
    rng = np.random.default_rng(3)
    w = 5
    tree = {
        "emb": jnp.asarray(rng.standard_normal((w, 33, 7)).astype(np.float32)),
        "blocks": [
            {"w1": jnp.asarray(rng.standard_normal((w, 11)).astype(np.float32))}
            for _ in range(3)
        ],
        "scalar": jnp.asarray(rng.standard_normal((w,)).astype(np.float32)),
    }
    lam = jnp.asarray(rng.random(w).astype(np.float32))
    lam = lam / lam.sum()
    out = ops.arena_combine(tree, lam, interpret=True)
    exp = combine_pytrees(tree, lam)
    for o, e in zip(jax.tree.leaves(out), jax.tree.leaves(exp)):
        assert o.shape == e.shape
        np.testing.assert_allclose(np.asarray(o), np.asarray(e), rtol=1e-5, atol=1e-6)


def test_arena_roundtrip_mixed_dtypes():
    """Arena flatten/unflatten preserves shapes, dtypes and values (ints
    below 2**24 round-trip exactly through the f32 arena)."""
    tree = {
        "a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
        "b": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
        "c": jnp.asarray(3.0, jnp.float32),
    }
    spec = AR.arena_spec(tree)
    vec = AR.to_arena(tree, spec)
    assert vec.shape == (6 + 2 + 1,) and vec.dtype == jnp.float32
    back = AR.from_arena(vec, spec)
    for o, e in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert o.dtype == e.dtype and o.shape == e.shape
        np.testing.assert_array_equal(np.asarray(o, np.float32), np.asarray(e, np.float32))
    # empty tree -> size-0 arena
    espec = AR.arena_spec(())
    assert espec.size == 0
    assert AR.to_arena((), espec).shape == (0,)
    assert AR.from_arena(jnp.zeros((0,)), espec) == ()


def test_stack_arena_roundtrip():
    rng = np.random.default_rng(4)
    w = 4
    tree = {"x": jnp.asarray(rng.standard_normal((w, 5, 2)).astype(np.float32)),
            "y": jnp.asarray(rng.standard_normal((w, 3)).astype(np.float32))}
    spec = AR.arena_spec(jax.tree.map(lambda l: l[0], tree))
    mat = AR.stack_to_arena(tree, spec)
    assert mat.shape == (w, 13)
    back = AR.stack_from_arena(mat, spec)
    for o, e in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(e))
