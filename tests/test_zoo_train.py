"""Anytime rounds end-to-end over the real model zoo (DESIGN.md §13):
MoE (deepseek-v2-lite) and SSM (xlstm) reduced configs run the whole
budget through RoundEngine as ONE jit dispatch, the ragged fused Pallas
path pins loss parity against the einsum/lax.scan reference path, and the
tree layout (the expert-parallel sharding home) matches the arena layout."""
import json

import numpy as np
import pytest

from repro.launch.train import main

_BASE = ["--reduced", "--rounds", "2", "--workers", "2", "--q-max", "2",
         "--seq-len", "32", "--local-batch", "2", "--n-seqs", "64",
         "--log-every", "100"]


def _run(tmp_path, monkeypatch, tag, args):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    m = tmp_path / f"{tag}.jsonl"
    loss = main(args + ["--metrics-file", str(m)])
    with open(m) as f:
        rows = [json.loads(line) for line in f]
    return float(loss), {r["round"]: r["loss"] for r in rows}


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "xlstm-350m"],
                         ids=["moe", "ssm"])
def test_zoo_anytime_rounds_kernel_loss_parity(arch, tmp_path, monkeypatch):
    """One MoE and one SSM config: the anytime budget runs end-to-end on
    the reference path AND the ragged fused Pallas path, losses are finite
    and decreasing, and the two paths' loss trajectories agree (the
    custom_vjp backward IS the reference vjp, so divergence is bounded by
    forward kernel numerics)."""
    base = ["--arch", arch] + _BASE
    loss_x, traj_x = _run(tmp_path, monkeypatch, "xla",
                          base + ["--kernel-impl", "xla"])
    loss_p, traj_p = _run(tmp_path, monkeypatch, "pallas",
                          base + ["--kernel-impl", "pallas_interpret"])
    assert np.isfinite(loss_x) and np.isfinite(loss_p)
    assert sorted(traj_x) == [0, 1]
    assert traj_x[1] < traj_x[0]  # training makes progress
    for r in traj_x:
        np.testing.assert_allclose(traj_p[r], traj_x[r], rtol=2e-3,
                                   err_msg=f"{arch} round {r}")


@pytest.mark.slow
def test_zoo_moe_tree_layout_matches_arena(tmp_path, monkeypatch):
    """The MoE config on the tree layout (where expert-parallel leaf
    shardings live) produces the same trajectory as the arena layout —
    same q-matrix, same index plan, float32-combine tolerance."""
    base = ["--arch", "deepseek-v2-lite-16b"] + _BASE
    _, traj_a = _run(tmp_path, monkeypatch, "arena", base)
    _, traj_t = _run(tmp_path, monkeypatch, "tree", base + ["--layout", "tree"])
    for r in traj_a:
        np.testing.assert_allclose(traj_t[r], traj_a[r], rtol=1e-5,
                                   err_msg=f"round {r}")


@pytest.mark.slow
def test_zoo_ssm_policies_run(tmp_path, monkeypatch):
    """The SSM config trains under both the anytime and uniform weightings
    (the zoo_bench scenario axes) without recompiling per round."""
    base = ["--arch", "xlstm-350m"] + _BASE
    for w in ("anytime", "uniform"):
        loss, traj = _run(tmp_path, monkeypatch, w, base + ["--weighting", w])
        assert np.isfinite(loss), w
        assert sorted(traj) == [0, 1], w
