"""Fused round kernel (kernels/fused_round) vs the unfused engine round.

All Pallas execution is interpret-mode (CPU); the contract under test is
semantic: one fused kernel == masked local_sgd scan + weighted_combine,
including q_v masking, q_v = 0 dropouts, LR schedules, and the K-round
driver / SweepEngine integrations behind RoundEngine(fused=...)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    RoundEngine,
    anytime_policy,
    async_policy,
    fused_mean_losses,
    sync_policy,
)
from repro.core.sweep import SweepEngine
from repro.data.linreg import make_linreg
from repro.kernels.fused_round import fused_round, fused_round_ref
from repro.optim import adam, sgd

W, QMAX, B, D = 6, 5, 8, 12


def _loss(params, mb):
    a, y = mb
    r = a @ params["x"] - y
    return jnp.mean(r * r)


@pytest.fixture(scope="module")
def lin():
    return make_linreg(600, D, seed=7)


def _batch(lin, rng, w=W, q=QMAX, b=B, k=None):
    shape = (w, q, b) if k is None else (k, w, q, b)
    idx = rng.integers(0, lin.m, size=shape)
    return (jnp.asarray(lin.A[idx], jnp.float32), jnp.asarray(lin.y[idx], jnp.float32))


def _params(rng):
    return {"x": jnp.asarray(rng.standard_normal(D), jnp.float32)}


def test_kernel_matches_ref(lin, rng):
    """Interpret-mode kernel == pure-jnp scan oracle, with q=0 workers."""
    a, y = _batch(lin, rng)
    x0 = jnp.asarray(rng.standard_normal(D), jnp.float32)
    q = jnp.asarray([5, 3, 0, 1, 4, 2], jnp.int32)
    lam = q / jnp.maximum(jnp.sum(q), 1)
    lrs = jnp.full((QMAX,), 0.01, jnp.float32)
    x_k, l_k = fused_round(a, y, x0, q, lam, lrs, interpret=True)
    x_r, l_r = fused_round_ref(a, y, x0, q, lam, lrs)
    np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_r), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), rtol=1e-5, atol=1e-6)
    # q = 0 worker accumulated zero loss and (weight 0) no combine mass
    assert float(l_k[2]) == 0.0


def test_kernel_scalar_prefetch_fallback_agrees(lin, rng):
    """scalar_prefetch=False (plain-input fallback) == prefetch path."""
    a, y = _batch(lin, rng)
    x0 = jnp.asarray(rng.standard_normal(D), jnp.float32)
    q = jnp.asarray([2, 5, 1, 0, 3, 4], jnp.int32)
    lam = q / jnp.maximum(jnp.sum(q), 1)
    x_p, l_p = fused_round(a, y, x0, q, lam, 0.01, interpret=True)
    x_f, l_f = fused_round(a, y, x0, q, lam, 0.01, interpret=True,
                           scalar_prefetch=False)
    np.testing.assert_allclose(np.asarray(x_p), np.asarray(x_f), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_f), rtol=1e-6)


def test_fused_engine_round_matches_unfused(lin, rng):
    """RoundEngine(fused='interpret') round == default engine round."""
    params = _params(rng)
    batch = _batch(lin, rng)
    q = jnp.asarray([4, 2, 0, 5, 1, 3], jnp.int32)
    eng_u = RoundEngine(_loss, sgd(0.01), W, QMAX, anytime_policy())
    eng_f = RoundEngine(_loss, sgd(0.01), W, QMAX, anytime_policy(),
                        fused="interpret")
    st_u, m_u = eng_u.round(eng_u.init_state(params, ()), batch, q)
    st_f, m_f = eng_f.round(eng_f.init_state(params, ()), batch, q)
    np.testing.assert_allclose(np.asarray(st_f.arena), np.asarray(st_u.arena),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m_f["loss"]), float(m_u["loss"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_f["lambdas"]),
                               np.asarray(m_u["lambdas"]), rtol=1e-6)


def test_fused_uniform_weighting(lin, rng):
    """Sync-style uniform weights route through the same fused kernel."""
    params = _params(rng)
    batch = _batch(lin, rng)
    q = jnp.full((W,), QMAX, jnp.int32)
    eng_u = RoundEngine(_loss, sgd(0.02), W, QMAX, sync_policy())
    eng_f = RoundEngine(_loss, sgd(0.02), W, QMAX, sync_policy(), fused="interpret")
    st_u, _ = eng_u.round(eng_u.init_state(params, ()), batch, q)
    st_f, _ = eng_f.round(eng_f.init_state(params, ()), batch, q)
    np.testing.assert_allclose(np.asarray(st_f.arena), np.asarray(st_u.arena),
                               rtol=1e-5, atol=1e-6)


def test_fused_lr_schedule(lin, rng):
    """Per-step LR schedules flow into the kernel via the lrs vector and
    advance with the round counter across driver rounds."""
    sched = lambda step: 0.02 / (1.0 + 0.1 * step.astype(jnp.float32))
    K = 3
    params = _params(rng)
    batches = _batch(lin, rng, k=K)
    q_mat = rng.integers(0, QMAX + 1, size=(K, W))
    eng_u = RoundEngine(_loss, sgd(sched), W, QMAX, anytime_policy())
    eng_f = RoundEngine(_loss, sgd(sched), W, QMAX, anytime_policy(),
                        fused="interpret")
    _, out_u = eng_u.run(eng_u.init_state(params, ()), batches, q_mat,
                         keep_history=True)
    _, out_f = eng_f.run(eng_f.init_state(params, ()), batches, q_mat,
                         keep_history=True)
    np.testing.assert_allclose(np.asarray(out_f["arena"]),
                               np.asarray(out_u["arena"]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_f["loss"]),
                               np.asarray(out_u["loss"]), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("batch_axis", [0, None])
def test_fused_through_sweep_engine(lin, rng, batch_axis):
    """Vmapped fused= composes with the [E]-batched SweepEngine driver,
    per-experiment ([E, K, ...]) and shared ([K, ...], batch_axis=None)
    batch streams (grid-axis fused='window*' parity lives in
    tests/test_fused_window.py)."""
    E, K = 3, 4
    params = _params(rng)
    shape = ((E, K, W, QMAX, B) if batch_axis == 0 else (K, W, QMAX, B))
    idx = rng.integers(0, lin.m, size=shape)
    batches = (jnp.asarray(lin.A[idx], jnp.float32),
               jnp.asarray(lin.y[idx], jnp.float32))
    qs = rng.integers(0, QMAX + 1, size=(E, K, W))
    eng_u = RoundEngine(_loss, sgd(0.02), W, QMAX, anytime_policy())
    eng_f = RoundEngine(_loss, sgd(0.02), W, QMAX, anytime_policy(),
                        fused="interpret")
    sw_u, sw_f = SweepEngine(eng_u), SweepEngine(eng_f)
    _, out_u = sw_u.run(sw_u.init_state(params, E), batches, qs,
                        keep_history=True, batch_axis=batch_axis)
    _, out_f = sw_f.run(sw_f.init_state(params, E), batches, qs,
                        keep_history=True, batch_axis=batch_axis)
    np.testing.assert_allclose(np.asarray(out_f["arena"]),
                               np.asarray(out_u["arena"]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_f["loss"]),
                               np.asarray(out_u["loss"]), rtol=1e-5, atol=1e-6)


def test_fused_loss_convention_shared_helper(lin, rng):
    """The ONE fused-loss normalization: kernel loss SUMS divided by
    max(q_v, 1) through `fused_mean_losses` equal the unfused engine's
    per-worker mean losses — fused and unfused metrics agree by
    construction, q = 0 workers report 0."""
    a, y = _batch(lin, rng)
    x0 = jnp.asarray(rng.standard_normal(D), jnp.float32)
    q = jnp.asarray([5, 3, 0, 1, 4, 2], jnp.int32)
    lam = q / jnp.maximum(jnp.sum(q), 1)
    _, loss_sums = fused_round(a, y, x0, q, lam, 0.01, interpret=True)
    losses = fused_mean_losses(loss_sums, q)
    # the tree-layout round reports the raw per-worker local_sgd means
    eng_t = RoundEngine(_loss, sgd(0.01), W, QMAX, anytime_policy(),
                        layout="tree")
    _, m = eng_t.round(eng_t.init_state({"x": x0}, ()), (a, y), q)
    np.testing.assert_allclose(np.asarray(losses),
                               np.asarray(m["worker_loss"]),
                               rtol=1e-5, atol=1e-6)
    # engine-level: weighted loss metric matches the unfused round exactly
    eng_f = RoundEngine(_loss, sgd(0.01), W, QMAX, anytime_policy(),
                        fused="interpret")
    _, m_f = eng_f.round(eng_f.init_state({"x": x0}, ()), (a, y), q)
    np.testing.assert_allclose(float(m_f["loss"]), float(m["loss"]),
                               rtol=1e-5, atol=1e-6)
    assert float(losses[2]) == 0.0  # q = 0: no steps, mean loss is 0
    # the helper broadcasts over leading axes (window [E, K, W] sums)
    stacked = fused_mean_losses(jnp.stack([loss_sums, loss_sums]),
                                jnp.stack([q, q]))
    np.testing.assert_allclose(np.asarray(stacked[0]), np.asarray(losses),
                               rtol=1e-6)


def test_fused_validation():
    with pytest.raises(ValueError):
        RoundEngine(_loss, sgd(0.1), W, QMAX, anytime_policy(), fused="bogus")
    with pytest.raises(ValueError):  # affine policy has no fused form
        RoundEngine(_loss, sgd(0.1), W, QMAX, async_policy(), fused="interpret")
    with pytest.raises(ValueError):  # stateful optimizer
        eng = RoundEngine(_loss, adam(0.1), W, QMAX, anytime_policy(),
                          fused="interpret")
        eng.init_state({"x": jnp.zeros(D, jnp.float32)})
    with pytest.raises(ValueError):  # multi-leaf params
        eng = RoundEngine(_loss, sgd(0.1), W, QMAX, anytime_policy(),
                          fused="interpret")
        eng.init_state({"x": jnp.zeros(D), "b": jnp.zeros(1)}, ())
