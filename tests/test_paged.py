"""Paged KV serving stack (ISSUE 8, DESIGN.md §12): BlockManager accounting,
paged-kernel parity vs the jnp oracle and the dense kernel, paged model-step
parity vs the dense decode path, and the anytime scheduler end to end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import decode_attention
from repro.kernels.paged_decode_attention import (
    paged_decode_attention,
    paged_decode_ref,
)
from repro.launch.scheduler import PagedScheduler, Request
from repro.models import model as M
from repro.models.kvcache import BlockManager


# ==========================================================================
# BlockManager
# ==========================================================================
def test_block_manager_prefix_sharing():
    bm = BlockManager(n_blocks=9, block_size=4)
    sb1 = bm.admit_prompt(list(range(10)), max_new=2)  # 12 tok -> 3 blocks
    assert len(sb1.blocks) == 3 and sb1.reserved == 0 and sb1.reused_len == 0
    bm.mark_written(sb1, 10)
    # same 8-token (2 full blocks) prefix -> contiguous reuse from the start
    sb2 = bm.admit_prompt(list(range(8)) + [99], max_new=3)
    assert sb2.blocks[:2] == sb1.blocks[:2]
    assert sb2.reused_len == 8 and bm.hits == 2
    assert sb2.blocks[2] != sb1.blocks[2]  # partial tails are never shared


def test_block_manager_reservation_makes_append_infallible():
    bm = BlockManager(n_blocks=5, block_size=4)
    sb = bm.admit_prompt(list(range(4)), max_new=6)  # 10 tok -> 1 + 2 reserved
    assert sb.reserved == 2
    assert bm.available() == 1  # reservation is excluded from admissions
    bm.append_block(sb)
    bm.append_block(sb)
    assert sb.reserved == 0
    with pytest.raises(AssertionError):
        bm.append_block(sb)  # outgrew its admission worst case


def test_block_manager_retire_parks_in_lru_and_rehits():
    bm = BlockManager(n_blocks=9, block_size=4)
    sb1 = bm.admit_prompt(list(range(8)), max_new=0)
    bm.mark_written(sb1, 8)
    shared = list(sb1.blocks)
    bm.retire(sb1)
    assert bm.stats()["cached"] == 2  # hashed blocks retained, not freed
    sb2 = bm.admit_prompt(list(range(8)), max_new=0)
    assert sb2.reused_len == 8 and sb2.blocks == shared


def test_block_manager_eviction_under_pressure():
    bm = BlockManager(n_blocks=4, block_size=2)
    for toks in ([1, 2], [3, 4]):
        sb = bm.admit_prompt(toks, max_new=0)
        bm.mark_written(sb, 2)
        bm.retire(sb)
    assert bm.stats()["cached"] == 2
    sb = bm.admit_prompt([5, 6, 7, 8, 9, 10], max_new=0)  # needs all 3 blocks
    assert sb is not None and bm.evictions >= 1
    bm.retire(sb)
    assert bm.stats()["free"] + bm.stats()["cached"] == 3  # nothing leaked


def test_block_manager_pending_blocks_not_reused():
    bm = BlockManager(n_blocks=6, block_size=2)
    bm.admit_prompt([1, 2, 3, 4], max_new=0)  # K/V never written
    sb2 = bm.admit_prompt([1, 2, 3, 4], max_new=0)
    assert sb2.reused_len == 0  # a hash hit on unwritten blocks is not a hit


def test_block_manager_admission_gate():
    bm = BlockManager(n_blocks=4, block_size=4)  # 3 usable (block 0 is null)
    assert bm.admit_prompt(list(range(4)), max_new=8) is not None
    assert bm.admit_prompt([1], max_new=0) is None  # pool exhausted
    assert bm.available() == 0


def test_block_manager_rewind_across_block_boundary():
    """Speculative writes that crossed into freshly appended tail blocks are
    truncated in O(released) bookkeeping: blocks return to the free list and
    the reservation is restored, so append_block stays infallible."""
    bm = BlockManager(n_blocks=8, block_size=4)
    sb = bm.admit_prompt(list(range(6)), max_new=10)  # 2 blocks + 2 reserved
    bm.mark_written(sb, 6)
    assert sb.reserved == 2
    bm.append_block(sb)
    bm.append_block(sb)  # draft window spilled across two block boundaries
    assert sb.reserved == 0 and len(sb.blocks) == 4
    freed = bm.rewind(sb, 7)  # accepted only 1 of the drafted tokens
    assert freed == 2 and len(sb.blocks) == 2
    assert sb.reserved == 2  # reservation restored...
    bm.append_block(sb)  # ...so regrowth cannot fail
    assert bm.rewind(sb, 7) == 1
    # rewind inside the kept tail block is pure bookkeeping: nothing freed
    assert bm.rewind(sb, 5) == 0 and len(sb.blocks) == 2


def test_block_manager_rewind_never_touches_cached_prefix():
    """A replayed fully-cached prompt shares its full blocks through the
    LRU; rewind after a rejected draft must release only the sequence's own
    tail and leave the shared hashed blocks (and their hashes) intact."""
    bm = BlockManager(n_blocks=10, block_size=4)
    sb1 = bm.admit_prompt(list(range(8)), max_new=0)
    bm.mark_written(sb1, 8)
    shared = list(sb1.blocks)
    bm.retire(sb1)  # both hashed blocks park in the prefix LRU
    sb2 = bm.admit_prompt(list(range(8)), max_new=6)  # full-prompt cache hit
    assert sb2.reused_len == 8 and sb2.blocks == shared
    bm.append_block(sb2)
    bm.append_block(sb2)  # speculate 6 tokens past the prompt
    assert bm.rewind(sb2, 9) == 1  # keep 1 accepted token past the prompt
    assert sb2.blocks[:2] == shared  # shared prefix untouched
    with pytest.raises(AssertionError):
        bm.rewind(sb2, 4)  # reaching INTO the hashed prefix is a bug
    assert sb2.blocks == shared  # it stopped at the hashed boundary
    bm.retire(sb2)
    sb3 = bm.admit_prompt(list(range(8)), max_new=0)
    assert sb3.reused_len == 8  # prefix cache still intact after the rewind


def test_block_manager_rewind_then_reclaim_pool_empty():
    """rewind + retire leaks nothing: every non-null block ends free or
    parked in the LRU, the reservation counter returns to zero, and no
    released block is left pending."""
    bm = BlockManager(n_blocks=12, block_size=4)
    sbs = []
    for i in range(3):
        sb = bm.admit_prompt(list(range(i, i + 5)), max_new=6)
        bm.mark_written(sb, 5)
        bm.append_block(sb)
        assert bm.rewind(sb, 6) == 1
        sbs.append(sb)
    for sb in sbs:
        bm.retire(sb)
    st = bm.stats()
    assert st["live"] == 0 and bm._reserved == 0
    assert st["free"] + st["cached"] == 11
    assert not bm._pending


# ==========================================================================
# Paged decode kernel
# ==========================================================================
def _paged_case(seed=0, nb=10, bs=8, b=3, h=8, hkv=2, dh=16, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    k_pool = jax.random.normal(ks[1], (nb, bs, hkv, dh), dtype)
    v_pool = jax.random.normal(ks[2], (nb, bs, hkv, dh), dtype)
    # permuted physical blocks; logical order only exists in the table
    tables = jnp.asarray([[3, 7, 1], [5, 2, 8], [9, 4, 6]], jnp.int32)
    seq_lens = jnp.asarray([24, 13, 0], jnp.int32)  # full / ragged / idle
    qmap = jnp.asarray([i // (h // hkv) for i in range(h)], jnp.int32)
    return q, k_pool, v_pool, tables, seq_lens, qmap


def test_paged_kernel_matches_oracle():
    q, kp, vp, tbl, lens, qmap = _paged_case()
    out = paged_decode_attention(q, kp, vp, tbl, lens, qmap, interpret=True)
    ref = paged_decode_ref(q, kp, vp, tbl, lens, qmap)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    # idle row (seq_len 0) is exactly zero, not mean(v)
    np.testing.assert_array_equal(np.asarray(out[2]), 0.0)


def test_paged_kernel_matches_dense_kernel():
    """Same attention, two layouts: gather the pool through the table into
    the dense [B, C] rectangle and the dense kernel must agree (rows with
    live context; the dense kernel leaves empty rows unspecified)."""
    q, kp, vp, tbl, lens, qmap = _paged_case()
    b, h, dh = q.shape
    bs = kp.shape[1]
    c = tbl.shape[1] * bs
    k = jnp.take(kp, tbl.reshape(-1), axis=0).reshape(b, c, -1, dh)
    v = jnp.take(vp, tbl.reshape(-1), axis=0).reshape(b, c, -1, dh)
    k = jnp.take(k, qmap, axis=2)
    v = jnp.take(v, qmap, axis=2)
    valid = jnp.arange(c)[None, :] < lens[:, None]
    dense = decode_attention(q, k, v, valid, bk=8, interpret=True)
    paged = paged_decode_attention(q, kp, vp, tbl, lens, qmap, interpret=True)
    live = np.asarray(lens) > 0
    np.testing.assert_allclose(paged[live], dense[live], rtol=1e-5, atol=1e-5)


def test_paged_kernel_bf16():
    q, kp, vp, tbl, lens, qmap = _paged_case(dtype=jnp.bfloat16)
    out = paged_decode_attention(q, kp, vp, tbl, lens, qmap, interpret=True)
    ref = paged_decode_ref(q, kp, vp, tbl, lens, qmap)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), rtol=3e-2, atol=3e-2
    )


# ==========================================================================
# Paged model step vs the dense decode path
# ==========================================================================
def _greedy_dense(cfg, params, toks, new):
    b, s = toks.shape
    cache = M.init_cache(cfg, b, s + new)
    logits, cache = M.prefill_bulk(params, cfg, toks, cache)
    out = [jnp.argmax(logits[:, : cfg.vocab], -1)]
    for i in range(new - 1):
        logits, cache = M.decode_step(params, cfg, cache, out[-1][:, None], s + i)
        out.append(jnp.argmax(logits[:, : cfg.vocab], -1))
    return np.stack([np.asarray(o) for o in out], 1)


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "minicpm3_4b"])
def test_paged_step_matches_dense_decode(arch):
    """Chunked paged prefill + paged decode == prefill_bulk + decode_step,
    for GQA (qwen2) and MLA latents (minicpm3)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = M.init(jax.random.PRNGKey(0), cfg)
    b, s, new, bs, chunk = 2, 7, 3, 4, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    want = _greedy_dense(cfg, params, toks, new)

    bm = BlockManager(n_blocks=32, block_size=bs)
    pool = M.init_paged_pool(cfg, 32, bs)
    sbs = [bm.admit_prompt([int(t) for t in np.asarray(toks[i])], new) for i in range(b)]
    nblk = max(len(sb.blocks) + sb.reserved for sb in sbs)

    def tables():
        t = np.zeros((b, nblk), np.int32)
        for i, sb in enumerate(sbs):
            t[i, : len(sb.blocks)] = sb.blocks
        return jnp.asarray(t)

    last = None
    for c0 in range(0, s, chunk):  # prefill in fixed-width chunks
        c1 = min(c0 + chunk, s)
        tk = jnp.pad(toks[:, c0:c1], ((0, 0), (0, chunk - (c1 - c0))))
        pos = np.full((b, chunk), -1, np.int32)
        pos[:, : c1 - c0] = np.arange(c0, c1)
        lg, pool = M.paged_step(params, cfg, pool, tables(), tk, jnp.asarray(pos))
        last = lg[:, (c1 - c0) - 1]
    out = [jnp.argmax(last[:, : cfg.vocab], -1)]
    for i in range(new - 1):
        pos = s + i
        for sb in sbs:
            if pos // bs >= len(sb.blocks):
                bm.append_block(sb)
        lg, pool = M.paged_step(
            params, cfg, pool, tables(), out[-1][:, None],
            jnp.full((b, 1), pos, jnp.int32),
        )
        out.append(jnp.argmax(lg[:, 0, : cfg.vocab], -1))
    got = np.stack([np.asarray(o) for o in out], 1)
    np.testing.assert_array_equal(got, want)


# ==========================================================================
# Anytime scheduler end to end
# ==========================================================================
def test_paged_scheduler_matches_isolated():
    cfg = dataclasses.replace(get_config("qwen2_0_5b").reduced(), dtype="float32")
    params = M.init(jax.random.PRNGKey(0), cfg)
    rngs = np.random.RandomState(7)
    shared = rngs.randint(0, cfg.vocab, 9).astype(np.int32)
    prompts = [
        np.concatenate([shared, rngs.randint(0, cfg.vocab, 4).astype(np.int32)]),
        np.concatenate([shared, rngs.randint(0, cfg.vocab, 2).astype(np.int32)]),
        rngs.randint(0, cfg.vocab, 23).astype(np.int32),  # chunked long prompt
    ]
    sch = PagedScheduler(cfg, params, n_slots=2, n_blocks=64, block_size=4,
                         chunk_tokens=8, deadline_ms=1e9)
    sch.submit(Request(0, prompts[0], 4))
    got = sch.run_to_completion()
    for i in (1, 2):
        sch.submit(Request(i, prompts[i], 4))
    got.update(sch.run_to_completion())
    for i, p in enumerate(prompts):
        want = _greedy_dense(cfg, params, jnp.asarray(p[None]), 4)[0].tolist()
        assert got[i] == want, (i, got[i], want)
    st = sch.stats()
    assert st["hits"] > 0  # requests 0/1 share two full prompt blocks
    assert st["live"] == 0 and st["free"] + st["cached"] == 63  # all reclaimed
