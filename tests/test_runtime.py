"""Real multi-process anytime runtime (core/runtime.py + launch/worker.py).

These tests spawn REAL worker processes: wall-clock deadlines, observed
q-vectors, protocol-only fault survival.  The contract under test is
DESIGN.md §11 — the master never stalls (every wait is bounded by
`RuntimeConfig.round_wall_bound`), degraded rounds are the x0 identity,
membership changes re-shard, and the observed window replays through the
RoundEngine oracle to float tolerance.

Kept deliberately small (linreg, W <= 3, short deadlines): each worker
process pays a jax import + jit warm-up, so fleets are shared per test,
not per assertion.
"""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.faults import FaultSpec
from repro.core.runtime import (AnytimeRuntime, RuntimeConfig, build_opt,
                                build_workload, replay_oracle)
from repro.data.linreg import make_linreg

D = 8
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


@pytest.fixture(scope="module")
def arrays():
    data = make_linreg(256, D, noise_std=0.1, seed=0)
    return {"a": np.asarray(data.A, np.float32),
            "y": np.asarray(data.y, np.float32)}


def _spec(opt="sgd"):
    kinds = {"sgd": {"kind": "sgd", "lr": 5e-3},
             "momentum": {"kind": "momentum", "lr": 5e-3, "beta": 0.9}}
    return {"workload": "linreg", "opt": kinds[opt]}


# ---------------------------------------------------------------------------
# config validation (cheap, no processes)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [
    {"n_workers": 0}, {"rounds": 0}, {"deadline_s": 0.0},
    {"deadline_s": -1.0}, {"q_max": 0}, {"evict_after": 0},
    {"retry_backoff_s": 0.0},
])
def test_config_validation(kw):
    with pytest.raises(ValueError):
        RuntimeConfig(**kw)


def test_round_wall_bound_is_finite_and_ordered():
    cfg = RuntimeConfig(deadline_s=0.2, report_grace_s=0.1,
                        report_retries=3, retry_backoff_s=0.05)
    assert cfg.round_wall_bound() == pytest.approx(0.2 + 0.1 + 0.05 * 7)


def test_build_workload_and_opt():
    arrays = {"a": np.zeros((4, D), np.float32), "y": np.zeros((4,), np.float32)}
    loss_fn, template = build_workload(_spec(), arrays)
    assert template["x"].shape == (D,)
    assert float(loss_fn(template, {k: v for k, v in arrays.items()})) == 0.0
    assert build_opt({"kind": "momentum", "lr": 0.1, "beta": 0.9}).spec["kind"] == "momentum"
    with pytest.raises(ValueError):
        build_opt({"kind": "rmsprop"})
    with pytest.raises(ValueError):
        build_workload({"workload": "tabular", "opt": {}}, arrays)


# ---------------------------------------------------------------------------
# the real fleet
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_deadline_rounds_and_oracle_parity(arrays):
    """Clean 6-round run: observed q > 0, loss trajectory finite, and the
    engine replay of the OBSERVED (q, index-plan) window reproduces the
    fleet's iterate to float tolerance."""
    cfg = RuntimeConfig(n_workers=2, rounds=6, deadline_s=0.25, q_max=6,
                        local_batch=8, seed=3)
    res = AnytimeRuntime(_spec("momentum"), arrays, cfg).run()
    assert len(res.q) == 6
    assert all(len(q) == 2 for q in res.q)
    assert np.asarray(res.q).sum() > 0
    assert np.all(np.isfinite(res.objective))
    # converging: late objective below the start
    assert res.objective[-1] < res.objective[0]
    o_losses, o_x = replay_oracle(_spec("momentum"), arrays, cfg, res)
    np.testing.assert_allclose(o_x, res.x_final, rtol=1e-4, atol=1e-5)
    mask = np.isfinite(res.losses)
    np.testing.assert_allclose(o_losses[mask], res.losses[mask],
                               rtol=1e-3, atol=1e-5)


@pytest.mark.slow
def test_fault_matrix_20_rounds_no_stall(arrays):
    """The acceptance matrix: kill, hang, slowdown, and dropped report at
    seeded rounds over a 20-round run.  The master must finish every round
    within its wall bound, survive the dead worker, degrade fault rounds
    to q_v = 0 for the faulted worker, and keep loss monotone on average."""
    cfg = RuntimeConfig(n_workers=3, rounds=20, deadline_s=0.12, q_max=4,
                        local_batch=8, seed=5, report_grace_s=0.2,
                        report_retries=2, retry_backoff_s=0.08)
    faults = FaultSpec.parse(
        "slow@3:1:0.5,drop@6:0,hang@9:1:0.8,kill@12:2,drop@15:0")
    t0 = time.monotonic()
    res = AnytimeRuntime(_spec(), arrays, cfg, fault_spec=faults).run()
    wall = time.monotonic() - t0
    assert len(res.q) == 20
    # no master stall: generous 3x bound per round + fleet spawn overhead
    assert wall < 20 * 3 * cfg.round_wall_bound() + 60, wall
    # the faulted worker contributed nothing in its fault round
    def q_of(rnd, wid):
        return dict(zip(res.members[rnd], res.q[rnd].tolist())).get(wid)
    assert q_of(3, 1) == 0      # slowdown > deadline
    assert q_of(6, 0) == 0      # dropped report
    assert q_of(9, 1) == 0      # hang burns the budget
    assert q_of(12, 2) == 0     # killed at round start
    # the kill is detected and the member removed (never blocks later rounds)
    assert any(e["event"] == "dead" and e["worker"] == 2 for e in res.events)
    assert all(2 not in m for m in res.members[14:])
    # survivors keep training: monotone-on-average objective
    obj = res.objective[np.isfinite(res.objective)]
    assert np.mean(obj[-5:]) < np.mean(obj[:5])
    # liveness: every non-fault round heard from every surviving worker
    q19 = res.q[19]
    assert len(q19) == 2 and np.all(q19 > 0)


@pytest.mark.slow
def test_all_miss_round_is_identity(arrays):
    """A round where EVERY worker misses the deadline (slowdown > T for
    both) must leave the iterate bit-identical — the master's combine is
    the x0 rebroadcast, not a zeroing division."""
    cfg = RuntimeConfig(n_workers=2, rounds=4, deadline_s=0.15, q_max=4,
                        local_batch=8, seed=7)
    # sleep > deadline forces q = 0, but short enough that the workers wake
    # inside round 1's retry window and rejoin cleanly for rounds 2-3
    faults = FaultSpec.parse("slow@1:0:0.4,slow@1:1:0.4")
    res = AnytimeRuntime(_spec(), arrays, cfg, fault_spec=faults).run()
    assert np.all(res.q[1] == 0)
    assert res.objective[1] == res.objective[0]  # identity round
    assert np.all(np.isfinite(res.objective))
    assert res.objective[-1] < res.objective[0]  # later rounds still train


@pytest.mark.slow
def test_elastic_leave_reshards_membership(arrays):
    """Master-scheduled retirement: the fleet shrinks at the round
    boundary, the survivor keeps training on a NEW membership epoch
    (re-sharded assignment), and the retired worker's id disappears."""
    cfg = RuntimeConfig(n_workers=2, rounds=6, deadline_s=0.15, q_max=4,
                        local_batch=8, seed=9, leave_schedule={3: (0,)})
    res = AnytimeRuntime(_spec(), arrays, cfg).run()
    assert res.members[2] == [0, 1]
    assert all(m == [1] for m in res.members[3:])
    assert any(e["event"] == "retire" and e["worker"] == 0 for e in res.events)
    assert res.epochs[3] > res.epochs[2]  # membership change = new epoch
    assert np.all(np.asarray(res.q[3:]).flatten() >= 0)
    assert res.objective[-1] < res.objective[0]


@pytest.mark.slow
def test_external_cli_worker_joins(arrays):
    """Elastic join via the CLI entrypoint: a worker launched with
    `python -m repro.launch.worker --address ... --authkey ...` is
    admitted and contributes from its first full round."""
    cfg = RuntimeConfig(n_workers=1, rounds=4, deadline_s=0.2, q_max=4,
                        local_batch=8, seed=11)
    rt = AnytimeRuntime(_spec(), arrays, cfg)
    rt.start()
    proc = None
    try:
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.worker",
             "--address", str(rt.address), "--authkey", rt.authkey.hex()],
            env={**os.environ, "PYTHONPATH": _SRC})
        t0 = time.monotonic()
        while time.monotonic() - t0 < 90:
            rt._pump_pending()
            if any(h.ready for h in rt._pending):
                break
            time.sleep(0.05)
        else:
            pytest.fail("external worker never became ready")
        res = rt.run()
    finally:
        if proc is not None:
            proc.terminate()
            proc.wait(timeout=10)
    assert res.members[0] == [0, 1]
    assert any(e["event"] == "join" and e["worker"] == 1 for e in res.events)
    assert np.asarray(res.q).sum() > 0


@pytest.mark.slow
def test_runtime_checkpoint_resume(arrays, tmp_path):
    """Crash recovery: a run checkpointing every 2 rounds resumes from its
    newest save into a NEW membership epoch and finishes the budget."""
    cfg = RuntimeConfig(n_workers=2, rounds=4, deadline_s=0.15, q_max=4,
                        local_batch=8, seed=13,
                        ckpt_dir=str(tmp_path / "rt"), ckpt_every=2)
    first = AnytimeRuntime(_spec(), arrays, cfg).run()
    assert np.all(np.isfinite(first.objective))
    cfg2 = RuntimeConfig(n_workers=2, rounds=6, deadline_s=0.15, q_max=4,
                         local_batch=8, seed=13,
                         ckpt_dir=str(tmp_path / "rt"), ckpt_every=2)
    rt2 = AnytimeRuntime(_spec(), arrays, cfg2, resume=True)
    assert rt2.start_round == 4
    np.testing.assert_allclose(rt2.x, first.x_final, atol=1e-7)
    res2 = rt2.run()
    assert res2.start_round == 4 and len(res2.q) == 2
    assert res2.epochs[0] > first.epochs[-1]
    assert res2.objective[-1] <= first.objective[0]


def test_q_matrix_rejects_ragged_membership(arrays):
    from repro.core.runtime import RuntimeResult

    res = RuntimeResult(
        x0=np.zeros(D), x_final=np.zeros(D), opt_final=np.zeros(0),
        losses=np.zeros(2), objective=np.zeros(2), round_wall_s=np.zeros(2),
        wall_clock_s=np.zeros(2), q=[np.zeros(2, np.int64), np.zeros(1, np.int64)],
        members=[[0, 1], [1]], index_plans=[], epochs=[0, 1], events=[])
    with pytest.raises(ValueError, match="membership changed"):
        res.q_matrix()
