"""End-to-end behaviour of the whole system (the paper's main claims,
wired through the real trainer/data/straggler stack)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnytimeConfig, anytime_round
from repro.core.straggler import StragglerModel, order_statistic_time
from repro.data import AnytimeBatcher, make_linreg
from repro.optim import sgd


def _loss(params, mb):
    r = mb["A"] @ params["x"] - mb["y"]
    return jnp.mean(r * r)


@pytest.mark.slow
def test_anytime_beats_sync_in_simulated_wallclock(rng):
    """Fig. 3, scaled down: error-vs-wall-clock; Anytime reaches the target
    error earlier than wait-for-all Sync under a heavy-tailed cluster."""
    lin = make_linreg(4000, 24, seed=0)
    w, qmax, b = 8, 10, 16
    smodel = StragglerModel(kind="pareto", alpha=1.3)
    batcher = AnytimeBatcher({"A": lin.A, "y": lin.y}, w, 0, qmax, b, seed=0)
    budget_t = 8.0  # ~8 steps at base speed; a couple under the tail

    def run(scheme):
        cfg = AnytimeConfig(n_workers=w, max_local_steps=qmax,
                            weighting="anytime" if scheme == "anytime" else "uniform")
        rnd = jax.jit(anytime_round(_loss, sgd(0.02), cfg))
        params = {"x": jnp.zeros(24, jnp.float32)}
        r = np.random.default_rng(7)
        wall, curve = 0.0, []
        for ep in range(30):
            batch = {k: jnp.asarray(v, jnp.float32) for k, v in batcher.round_batch().items()}
            if scheme == "anytime":
                q = smodel.realize_steps(r, w, budget_t, qmax)
                wall += budget_t
            else:  # sync: every worker must finish qmax steps, wait for max
                finish = smodel.finishing_times(r, w, qmax)
                wall += order_statistic_time(finish, w)
                q = np.full(w, qmax)
            params, _, _ = rnd(params, (), batch, jnp.asarray(q, jnp.int32))
            curve.append((wall, lin.normalized_error(np.asarray(params["x"], np.float64))))
        return curve

    any_curve = run("anytime")
    sync_curve = run("sync")

    def time_to(curve, target):
        for t, e in curve:
            if e < target:
                return t
        return np.inf

    target = 0.25
    t_any, t_sync = time_to(any_curve, target), time_to(sync_curve, target)
    assert t_any < t_sync, (t_any, t_sync, any_curve[-1], sync_curve[-1])


def test_train_driver_loss_decreases():
    from repro.launch.train import main
    loss = main([
        "--arch", "qwen2-0.5b", "--reduced", "--rounds", "8", "--workers", "4",
        "--q-max", "2", "--seq-len", "32", "--local-batch", "2",
        "--n-seqs", "128", "--lr", "3e-3", "--log-every", "100",
    ])
    assert np.isfinite(loss) and loss < 6.3  # ln(512) ~ 6.24 start


def test_train_driver_with_persistent_stragglers_and_checkpoint(tmp_path):
    from repro.launch.train import main
    loss = main([
        "--arch", "hymba-1.5b", "--reduced", "--rounds", "4", "--workers", "4",
        "--q-max", "2", "--seq-len", "32", "--local-batch", "2", "--s", "1",
        "--persistent-frac", "0.25", "--n-seqs", "64", "--ckpt-dir", str(tmp_path),
        "--log-every", "100",
    ])
    assert np.isfinite(loss)
    assert len(list(tmp_path.glob("step_*.ckpt"))) >= 1


def test_roofline_parser():
    from repro.launch.roofline import Roofline, collective_bytes
    hlo = """
      %all-reduce.1 = f32[128,256]{1,0} all-reduce(%x), replica_groups=...
      %ag = bf16[64]{0} all-gather(%y), dimensions={0}
      %other = f32[2,2]{1,0} add(%a, %b)
      %rs.5 = (f32[16]{0}, f32[16]{0}) reduce-scatter(%c, %d), dimensions={0}
    """
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 128 * 256 * 4
    assert cb["all-gather"] == 64 * 2
    assert cb["reduce-scatter"] == 2 * 16 * 4
    r = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, coll_by_kind=cb)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")


def test_dryrun_results_complete():
    """The committed dry-run sweep must cover all 10 archs x 4 shapes x 2
    meshes with zero failures (skips only where DESIGN.md §4 says so)."""
    import json
    import pathlib

    outdir = pathlib.Path(__file__).parent.parent / "results" / "dryrun"
    if not outdir.exists():
        pytest.skip("dry-run sweep not generated yet")
    files = list(outdir.glob("*.json"))
    assert len(files) == 80, f"expected 80 combos, found {len(files)}"
    statuses = {}
    for f in files:
        statuses[f.stem] = json.loads(f.read_text())["status"]
    fails = [k for k, v in statuses.items() if v not in ("ok", "skipped")]
    assert not fails, fails
    skips = [k for k, v in statuses.items() if v == "skipped"]
    assert sorted(skips) == [
        "seamless_m4t_medium__long_500k__16x16",
        "seamless_m4t_medium__long_500k__2x16x16",
    ]
